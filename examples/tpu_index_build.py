"""The Border-Labeling index build as one JAX program (the TPU path).

Shows the composable core module: dense packed districts → vmapped
Bellman-Ford (stage A) → overlay closure (stage B) → full-table min-plus
(stage C) → rank-ordered prune (stage D), with the Pallas kernels
switched in (interpret mode on CPU; native on TPU), validated against the
Dijkstra-based reference builder.

    PYTHONPATH=src python examples/tpu_index_build.py
"""
import time

import numpy as np

from repro.core import (bfs_grow_partition, build_border_labels_reference,
                        grid_road_network)
from repro.core.jax_builder import build_border_labels_jax


def main() -> None:
    g = grid_road_network(24, 24, seed=5)
    part = bfs_grow_partition(g, 6, seed=0)

    t0 = time.perf_counter()
    ref = build_border_labels_reference(g, part)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    jax_bl = build_border_labels_jax(g, part, use_pallas=True)
    t_jax = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    ss = rng.integers(0, g.num_vertices, size=200)
    ts = rng.integers(0, g.num_vertices, size=200)
    np.testing.assert_allclose(jax_bl.query_many(ss, ts),
                               ref.query_many(ss, ts), rtol=1e-5)
    print(f"reference (pruned Dijkstra) : {t_ref*1e3:7.1f} ms")
    print(f"JAX pipeline (Pallas interp): {t_jax*1e3:7.1f} ms "
          f"(CPU interpreter — compiles natively on TPU)")
    print(f"borders={jax_bl.num_borders}, "
          f"index={jax_bl.size_bytes()/1e6:.2f} MB — answers match on "
          f"200 random queries")


if __name__ == "__main__":
    main()
